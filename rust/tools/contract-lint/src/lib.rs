//! contract-lint — a zero-registry-dependency, token-level source linter
//! that turns the bwma repo's load-bearing prose contracts into
//! machine-checked gates (std only, no `syn`: the offline crate cache is
//! the whole point of this workspace).
//!
//! Rules (see `rust/DESIGN.md` "Static guarantees" for the full spec):
//!
//! * **safety-comment** — every `unsafe` keyword in `rust/src` is
//!   immediately preceded (same line, or above across doc/attribute
//!   lines) by a comment containing `SAFETY` or `# Safety`.
//! * **thread-containment** — `thread::spawn` / `thread::scope` appear
//!   nowhere in `rust/src` outside `runtime/parallel.rs`: the worker
//!   pool is the only thread factory for compute (the serving event loop
//!   uses `thread::Builder`, which stays auditable by name).
//! * **hotpath-alloc** — no allocation idioms (`Vec::new`, `vec!`,
//!   `.to_vec(`, `.clone()`, `Box::new`, `format!`, `.collect()`, …)
//!   inside any function listed in the hot-path manifest
//!   (`hotpath.txt`); a manifest entry whose function cannot be found is
//!   itself a violation, so the manifest cannot silently rot.
//! * **verify-tags** — every tag string registered in
//!   `runtime/native.rs::native_tags()` appears (quoted) in at least one
//!   file under `rust/tests/`.
//! * **coordinator-unwrap** — no `.unwrap()` in non-test code under
//!   `rust/src/coordinator/` (typed errors or `expect` with an invariant
//!   message).
//! * **forbid-unsafe** — the modules that need no unsafe (`accel`,
//!   `analysis`, `config`, `coordinator`, `layout`, `mem`, `sim`,
//!   `workload`) carry `#![forbid(unsafe_code)]`.
//!
//! The scanner is deliberately token-level, not a parser: each source
//! line is split into *code* (string/char-literal contents blanked,
//! comments removed) and *comment* text by a small state machine that
//! understands line comments, nested block comments, (raw) string
//! literals, and char-literal-vs-lifetime disambiguation. Rules then
//! match word-bounded tokens against the code text only, so `unsafe` in
//! a doc string or `.unwrap()` in an error message never false-positive.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One rule violation, rendered as `file:line: [rule] message` — the
/// `file:line` prefix is the CI-clickable diagnostic format the
/// acceptance tests pin.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Path relative to the repo root, `/`-separated.
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    /// Rule id (kebab-case).
    pub rule: &'static str,
    /// Human-readable explanation.
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Where to lint: `root` is the repository root (the directory holding
/// `rust/`), `manifest` the hot-path manifest file.
pub struct LintConfig {
    pub root: PathBuf,
    pub manifest: PathBuf,
}

/// One scanned source line.
#[derive(Debug, Default)]
struct Line {
    /// Code text: comments removed, string/char contents blanked (the
    /// delimiting quotes are kept so token positions stay meaningful).
    code: String,
    /// Comment text (line + block comments), markers included.
    comment: String,
    /// The raw source line (used only where literal text is needed,
    /// e.g. extracting the tag strings out of `native_tags()`).
    raw: String,
}

/// A scanned file: lines plus a per-line "inside `#[cfg(test)]`" mask.
struct SourceFile {
    rel: String,
    lines: Vec<Line>,
    in_test: Vec<bool>,
}

// ---------------------------------------------------------------------------
// Lexing: raw source → (code, comment) per line.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(usize),
    Char,
}

fn is_word(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn scan_source(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut state = State::Code;
    let mut i = 0;

    while i < n {
        let c = chars[i];
        if c == '\n' {
            // Line boundary in any state (block comments and strings
            // continue on the next line).
            if state == State::LineComment {
                state = State::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    state = State::LineComment;
                    cur.comment.push_str("//");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    state = State::Str;
                    i += 1;
                } else if c == 'r' && (i == 0 || !is_word_char(chars[i - 1])) {
                    // `r"…"` / `r#"…"#` raw string — or a plain `r`
                    // identifier char / `r#raw_ident`.
                    match raw_str_hashes(&chars, i) {
                        Some(hashes) => {
                            cur.code.push_str("r\"");
                            state = State::RawStr(hashes);
                            i += 2 + hashes; // r, hashes, opening quote
                        }
                        None => {
                            cur.code.push('r');
                            i += 1;
                        }
                    }
                } else if c == '\'' {
                    // Lifetime or char literal: a char literal is an
                    // escape ('\x') or a single char followed by a
                    // closing quote ('x'); everything else is a
                    // lifetime.
                    if next == Some('\\') {
                        cur.code.push('\'');
                        state = State::Char;
                        i += 1;
                    } else if chars.get(i + 2) == Some(&'\'') && next != Some('\'') {
                        cur.code.push_str("'_'");
                        i += 3;
                    } else {
                        cur.code.push('\'');
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if depth == 1 { State::Code } else { State::BlockComment(depth - 1) };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str | State::Char => {
                let close = if state == State::Str { '"' } else { '\'' };
                if c == '\\' {
                    // Consume the escaped char too — unless it is a
                    // newline (line continuation), which the main loop
                    // must see to keep line numbers honest.
                    if chars.get(i + 1).is_some_and(|&e| e != '\n') {
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == close {
                    cur.code.push(close);
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1; // blank literal contents
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && (1..=hashes).all(|k| chars.get(i + k) == Some(&'#')) {
                    cur.code.push('"');
                    state = State::Code;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    // Raw text comes straight from the input: the state machine above
    // only produces code/comment splits, so it cannot desynchronize the
    // raw view.
    for (line, raw) in lines.iter_mut().zip(src.lines()) {
        line.raw = raw.to_string();
    }
    lines
}

fn is_word_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// If position `i` (which holds `r`) starts a raw string literal,
/// return its hash count.
fn raw_str_hashes(chars: &[char], i: usize) -> Option<usize> {
    let mut j = i + 1;
    let mut hashes = 0;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    (chars.get(j) == Some(&'"')).then_some(hashes)
}

/// Mark the line span of every `#[cfg(test)]`-gated item (brace-matched
/// from the attribute; a `;` before any `{` ends a braceless item).
fn mark_test_regions(lines: &[Line]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        let Some(pos) = lines[i].code.find("#[cfg(test)]") else {
            i += 1;
            continue;
        };
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut end = lines.len() - 1;
        'scan: for (j, line) in lines.iter().enumerate().skip(i) {
            let code = if j == i { &line.code[pos..] } else { line.code.as_str() };
            for b in code.bytes() {
                match b {
                    b'{' => {
                        depth += 1;
                        opened = true;
                    }
                    b'}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            end = j;
                            break 'scan;
                        }
                    }
                    b';' if !opened => {
                        end = j;
                        break 'scan;
                    }
                    _ => {}
                }
            }
        }
        for t in in_test.iter_mut().take(end + 1).skip(i) {
            *t = true;
        }
        i = end + 1;
    }
    in_test
}

fn parse_source(rel: String, text: &str) -> SourceFile {
    let lines = scan_source(text);
    let in_test = mark_test_regions(&lines);
    SourceFile { rel, lines, in_test }
}

// ---------------------------------------------------------------------------
// Token matching.
// ---------------------------------------------------------------------------

/// Find `tok` in `code` as a word-bounded token: where the token starts
/// (ends) with an identifier char, the preceding (following) char must
/// not be one — so `unsafe` never matches inside `unsafe_op_in_unsafe_fn`
/// and `to_vec` never matches inside `into_vector`.
fn find_token(code: &str, tok: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    let tb = tok.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(tok) {
        let abs = start + pos;
        let end = abs + tok.len();
        let pre_ok = !is_word(tb[0]) || abs == 0 || !is_word(bytes[abs - 1]);
        let post_ok = !is_word(tb[tok.len() - 1]) || end >= bytes.len() || !is_word(bytes[end]);
        if pre_ok && post_ok {
            return Some(abs);
        }
        start = abs + 1;
    }
    None
}

fn has_token(code: &str, tok: &str) -> bool {
    find_token(code, tok).is_some()
}

// ---------------------------------------------------------------------------
// Rules.
// ---------------------------------------------------------------------------

fn comment_has_safety_marker(comment: &str) -> bool {
    comment.contains("SAFETY") || comment.contains("# Safety")
}

/// safety-comment: an `unsafe` token must carry a marker on its own
/// line, or on a comment line directly above — doc comments, attribute
/// lines, and further comment lines may sit between, a blank line or
/// real code breaks adjacency.
fn rule_safety_comment(files: &[SourceFile], diags: &mut Vec<Diagnostic>) {
    for f in files {
        for (idx, line) in f.lines.iter().enumerate() {
            if !has_token(&line.code, "unsafe") {
                continue;
            }
            if comment_has_safety_marker(&line.comment) {
                continue;
            }
            let mut documented = false;
            let mut j = idx;
            while j > 0 {
                j -= 1;
                let above = &f.lines[j];
                if comment_has_safety_marker(&above.comment) {
                    documented = true;
                    break;
                }
                let code = above.code.trim();
                if code.is_empty() && above.comment.is_empty() {
                    break; // blank line: adjacency broken
                }
                if code.is_empty() || code.starts_with("#[") || code.starts_with("#!") {
                    continue; // unmarked comment / attribute: keep looking up
                }
                break; // real code intervenes
            }
            if !documented {
                diags.push(Diagnostic {
                    file: f.rel.clone(),
                    line: idx + 1,
                    rule: "safety-comment",
                    msg: "`unsafe` without an immediately preceding `// SAFETY:` comment"
                        .to_string(),
                });
            }
        }
    }
}

/// thread-containment: compute threads come from the worker pool only.
fn rule_thread_containment(files: &[SourceFile], diags: &mut Vec<Diagnostic>) {
    for f in files {
        if f.rel == "rust/src/runtime/parallel.rs" {
            continue;
        }
        for (idx, line) in f.lines.iter().enumerate() {
            if f.in_test[idx] {
                continue;
            }
            for tok in ["thread::spawn", "thread::scope"] {
                if has_token(&line.code, tok) {
                    diags.push(Diagnostic {
                        file: f.rel.clone(),
                        line: idx + 1,
                        rule: "thread-containment",
                        msg: format!(
                            "`{tok}` outside runtime/parallel.rs — all compute threads \
                             must come from WorkerPool"
                        ),
                    });
                }
            }
        }
    }
}

/// coordinator-unwrap: no `.unwrap()` in non-test coordinator code.
fn rule_coordinator_unwrap(files: &[SourceFile], diags: &mut Vec<Diagnostic>) {
    for f in files {
        if !f.rel.starts_with("rust/src/coordinator/") {
            continue;
        }
        for (idx, line) in f.lines.iter().enumerate() {
            if f.in_test[idx] {
                continue;
            }
            if has_token(&line.code, ".unwrap()") {
                diags.push(Diagnostic {
                    file: f.rel.clone(),
                    line: idx + 1,
                    rule: "coordinator-unwrap",
                    msg: "`.unwrap()` under coordinator/ — use a typed error path or \
                          `expect` with an invariant message"
                        .to_string(),
                });
            }
        }
    }
}

/// Modules that must compile under `#![forbid(unsafe_code)]`.
const FORBID_UNSAFE_MODULES: [&str; 8] =
    ["accel", "analysis", "config", "coordinator", "layout", "mem", "sim", "workload"];

/// forbid-unsafe: safe modules declare it at the crate boundary.
fn rule_forbid_unsafe(files: &[SourceFile], diags: &mut Vec<Diagnostic>) {
    for module in FORBID_UNSAFE_MODULES {
        let rel = format!("rust/src/{module}/mod.rs");
        let Some(f) = files.iter().find(|f| f.rel == rel) else {
            continue; // module not present (fixture trees)
        };
        if !f.lines.iter().any(|l| l.code.contains("#![forbid(unsafe_code)]")) {
            diags.push(Diagnostic {
                file: f.rel.clone(),
                line: 1,
                rule: "forbid-unsafe",
                msg: format!("module `{module}` must declare #![forbid(unsafe_code)]"),
            });
        }
    }
}

/// Allocation idioms banned from hot-path functions. `.to_vec(` and
/// `.to_string(` are matched with the open paren so the *names* of the
/// rules can still be spelled in nearby comments.
const ALLOC_TOKENS: [&str; 13] = [
    "Vec::new",
    "vec!",
    ".to_vec(",
    ".clone()",
    "Box::new",
    "format!",
    ".collect()",
    ".collect::",
    "String::new",
    ".to_string(",
    ".to_owned(",
    "with_capacity",
    "Arc::new",
];

/// Line span (inclusive, 0-based) of `fn name` in `file`, located by
/// token matching plus brace counting.
fn find_fn_span(file: &SourceFile, name: &str) -> Option<(usize, usize)> {
    for (i, line) in file.lines.iter().enumerate() {
        let Some(pos) = find_token(&line.code, name) else {
            continue;
        };
        // The token must be a function name: preceded by the `fn`
        // keyword, followed by `(` or generics.
        let before = line.code[..pos].trim_end();
        if !before.ends_with("fn") {
            continue;
        }
        if before.len() >= 3 && is_word(before.as_bytes()[before.len() - 3]) {
            continue; // e.g. `spawn_fn` — not the keyword
        }
        let after = line.code[pos + name.len()..].trim_start();
        if !(after.starts_with('(') || after.starts_with('<') || after.is_empty()) {
            continue;
        }
        // Brace-match the body from the declaration onward.
        let mut depth: i64 = 0;
        let mut opened = false;
        for (j, l) in file.lines.iter().enumerate().skip(i) {
            let code = if j == i { &l.code[pos..] } else { l.code.as_str() };
            for b in code.bytes() {
                match b {
                    b'{' => {
                        depth += 1;
                        opened = true;
                    }
                    b'}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            return Some((i, j));
                        }
                    }
                    b';' if !opened => return Some((i, j)), // prototype
                    _ => {}
                }
            }
        }
        return Some((i, file.lines.len() - 1));
    }
    None
}

/// hotpath-alloc: manifest-listed functions must not touch the heap.
fn rule_hotpath_alloc(files: &[SourceFile], manifest: &str, diags: &mut Vec<Diagnostic>) {
    for (lineno, entry) in manifest.lines().enumerate() {
        let entry = entry.trim();
        if entry.is_empty() || entry.starts_with('#') {
            continue;
        }
        let mut parts = entry.split_whitespace();
        let (Some(path), Some(name), None) = (parts.next(), parts.next(), parts.next()) else {
            diags.push(Diagnostic {
                file: "hotpath.txt".to_string(),
                line: lineno + 1,
                rule: "hotpath-alloc",
                msg: format!("malformed manifest entry {entry:?} (want `<path> <fn>`)"),
            });
            continue;
        };
        let rel = format!("rust/{path}");
        let Some(f) = files.iter().find(|f| f.rel == rel) else {
            diags.push(Diagnostic {
                file: rel,
                line: 1,
                rule: "hotpath-alloc",
                msg: format!("manifest file not found (entry `{path} {name}`)"),
            });
            continue;
        };
        let Some((start, end)) = find_fn_span(f, name) else {
            diags.push(Diagnostic {
                file: f.rel.clone(),
                line: 1,
                rule: "hotpath-alloc",
                msg: format!("manifest fn `{name}` not found"),
            });
            continue;
        };
        for idx in start..=end {
            for tok in ALLOC_TOKENS {
                if has_token(&f.lines[idx].code, tok) {
                    diags.push(Diagnostic {
                        file: f.rel.clone(),
                        line: idx + 1,
                        rule: "hotpath-alloc",
                        msg: format!("allocation idiom `{tok}` in hot-path fn `{name}`"),
                    });
                }
            }
        }
    }
}

/// Pull the quoted string literals out of raw source text (comments
/// skipped, escapes honoured).
fn string_literals(raw: &str) -> Vec<String> {
    let chars: Vec<char> = raw.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        match chars[i] {
            '/' if chars.get(i + 1) == Some(&'/') => {
                while i < chars.len() && chars[i] != '\n' {
                    i += 1;
                }
            }
            '"' => {
                let mut lit = String::new();
                i += 1;
                while i < chars.len() && chars[i] != '"' {
                    if chars[i] == '\\' {
                        i += 1;
                    }
                    if i < chars.len() {
                        lit.push(chars[i]);
                        i += 1;
                    }
                }
                i += 1;
                out.push(lit);
            }
            _ => i += 1,
        }
    }
    out
}

/// verify-tags: every tag registered in `native_tags()` must appear,
/// quoted, somewhere under `rust/tests/`.
fn rule_verify_tags(
    files: &[SourceFile],
    tests_dir: &Path,
    diags: &mut Vec<Diagnostic>,
) -> io::Result<()> {
    let Some(f) = files.iter().find(|f| f.rel == "rust/src/runtime/native.rs") else {
        return Ok(()); // fixture tree without a tag registry
    };
    let Some((start, end)) = find_fn_span(f, "native_tags") else {
        return Ok(());
    };
    let body: String =
        f.lines[start..=end].iter().map(|l| l.raw.as_str()).collect::<Vec<_>>().join("\n");
    let tags = string_literals(&body);
    if tags.is_empty() || !tests_dir.is_dir() {
        return Ok(());
    }
    let mut test_text = String::new();
    let mut test_files = Vec::new();
    collect_rs(tests_dir, &mut test_files)?;
    for path in test_files {
        test_text.push_str(&fs::read_to_string(&path)?);
        test_text.push('\n');
    }
    for tag in tags {
        if !test_text.contains(&format!("\"{tag}\"")) {
            diags.push(Diagnostic {
                file: f.rel.clone(),
                line: start + 1,
                rule: "verify-tags",
                msg: format!("verify tag \"{tag}\" appears in no test under rust/tests/"),
            });
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Driver.
// ---------------------------------------------------------------------------

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> =
        fs::read_dir(dir)?.collect::<Result<Vec<_>, _>>()?.into_iter().map(|e| e.path()).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

/// Lint the repository at `cfg.root`, returning all diagnostics sorted
/// by file and line (empty = every contract holds).
pub fn lint_repo(cfg: &LintConfig) -> io::Result<Vec<Diagnostic>> {
    let src_dir = cfg.root.join("rust").join("src");
    if !src_dir.is_dir() {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("{} is not a repo root (no rust/src)", cfg.root.display()),
        ));
    }
    let mut paths = Vec::new();
    collect_rs(&src_dir, &mut paths)?;
    let mut files = Vec::new();
    for path in &paths {
        let text = fs::read_to_string(path)?;
        files.push(parse_source(rel_path(&cfg.root, path), &text));
    }
    let manifest = fs::read_to_string(&cfg.manifest).map_err(|e| {
        io::Error::new(e.kind(), format!("hot-path manifest {}: {e}", cfg.manifest.display()))
    })?;

    let mut diags = Vec::new();
    rule_safety_comment(&files, &mut diags);
    rule_thread_containment(&files, &mut diags);
    rule_coordinator_unwrap(&files, &mut diags);
    rule_forbid_unsafe(&files, &mut diags);
    rule_hotpath_alloc(&files, &manifest, &mut diags);
    rule_verify_tags(&files, &cfg.root.join("rust").join("tests"), &mut diags)?;
    diags.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
    Ok(diags)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scanner_strips_comments_and_blanks_strings() {
        let src = "let s = \"unsafe .unwrap()\"; // unsafe in comment\n\
                   let c = 'x'; /* block\n\
                   unsafe */ let l: &'static str = \"\";\n";
        let lines = scan_source(src);
        assert_eq!(lines.len(), 3);
        assert!(!has_token(&lines[0].code, "unsafe"));
        assert!(!has_token(&lines[0].code, ".unwrap()"));
        assert!(lines[0].comment.contains("unsafe in comment"));
        assert_eq!(lines[1].code, "let c = '_'; ");
        assert!(!has_token(&lines[2].code, "unsafe"));
        assert!(lines[2].code.contains("'static"));
    }

    #[test]
    fn raw_strings_and_raw_identifiers() {
        let lines = scan_source("let a = r\"unsafe\"; let b = r#\"x .unwrap() \"#;\n");
        assert!(!has_token(&lines[0].code, "unsafe"));
        assert!(!has_token(&lines[0].code, ".unwrap()"));
        // `r#match`-style raw identifiers must not start a string.
        let lines = scan_source("let r#match = 1; let after = r#match + 1;\n");
        assert!(lines[0].code.contains("after"));
    }

    #[test]
    fn token_boundaries_reject_substrings() {
        assert!(has_token("unsafe {", "unsafe"));
        assert!(!has_token("#![deny(unsafe_op_in_unsafe_fn)]", "unsafe"));
        assert!(!has_token("#![forbid(unsafe_code)]", "unsafe"));
        assert!(has_token("std::thread::spawn(|| {})", "thread::spawn"));
        assert!(!has_token("my_thread::spawner()", "thread::spawn"));
        assert!(has_token("x.unwrap()", ".unwrap()"));
        assert!(!has_token("x.unwrap_or(0)", ".unwrap()"));
    }

    #[test]
    fn cfg_test_regions_are_masked() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn b() {}\n";
        let lines = scan_source(src);
        let mask = mark_test_regions(&lines);
        assert_eq!(mask, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn fn_spans_are_brace_matched() {
        let src = "fn outer() {\n    let f = || { 1 };\n    f()\n}\nfn other() {}\n";
        let f = parse_source("x.rs".to_string(), src);
        assert_eq!(find_fn_span(&f, "outer"), Some((0, 3)));
        assert_eq!(find_fn_span(&f, "other"), Some((4, 4)));
        assert_eq!(find_fn_span(&f, "missing"), None);
    }

    #[test]
    fn safety_walkup_accepts_attributes_and_doc_blocks() {
        let src = "\
/// # Safety
/// caller keeps `p` alive.
#[inline]
pub unsafe fn deref(p: *const u8) -> u8 {
    // SAFETY: forwarded contract.
    unsafe { *p }
}
";
        let files = [parse_source("rust/src/runtime/x.rs".to_string(), src)];
        let mut diags = Vec::new();
        rule_safety_comment(&files, &mut diags);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn blank_line_breaks_safety_adjacency() {
        let src = "// SAFETY: stale comment.\n\nlet x = unsafe { f() };\n";
        let files = [parse_source("rust/src/runtime/x.rs".to_string(), src)];
        let mut diags = Vec::new();
        rule_safety_comment(&files, &mut diags);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].line, 3);
    }
}
