//! CLI driver: `cargo run -p contract-lint [-- --root <repo> --manifest <file>]`.
//!
//! Exit 0 when every contract holds; exit 1 with one `file:line: [rule]
//! message` diagnostic per violation otherwise — the blocking CI gate
//! (.github/workflows/ci.yml, job `contracts`).

use std::path::PathBuf;
use std::process::ExitCode;

use contract_lint::{lint_repo, LintConfig};

fn opt(args: &[String], name: &str) -> Option<PathBuf> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(PathBuf::from)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // Under `cargo run -p contract-lint` the manifest dir is
    // <repo>/rust/tools/contract-lint; three ancestors up is the root.
    let root = opt(&args, "--root")
        .or_else(|| {
            std::env::var_os("CARGO_MANIFEST_DIR")
                .map(|d| PathBuf::from(d).join("..").join("..").join(".."))
        })
        .unwrap_or_else(|| PathBuf::from("."));
    let manifest = opt(&args, "--manifest").unwrap_or_else(|| {
        root.join("rust").join("tools").join("contract-lint").join("hotpath.txt")
    });

    match lint_repo(&LintConfig { root, manifest }) {
        Ok(diags) if diags.is_empty() => {
            println!("contract-lint: OK — all contracts hold");
            ExitCode::SUCCESS
        }
        Ok(diags) => {
            for d in &diags {
                println!("{d}");
            }
            eprintln!("contract-lint: {} violation(s)", diags.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("contract-lint: error: {e}");
            ExitCode::FAILURE
        }
    }
}
