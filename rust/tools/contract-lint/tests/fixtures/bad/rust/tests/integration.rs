#[test]
fn runs_tag_a() {
    assert!(!"tag_a".is_empty());
}
