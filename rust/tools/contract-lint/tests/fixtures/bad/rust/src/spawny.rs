pub fn sneaky() {
    std::thread::spawn(|| {});
}
