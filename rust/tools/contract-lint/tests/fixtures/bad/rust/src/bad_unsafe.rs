pub fn no_comment() -> u8 {
    let x = 1u8;
    unsafe { core::ptr::read(&x) }
}
