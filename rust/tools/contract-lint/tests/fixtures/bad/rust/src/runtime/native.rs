pub fn native_tags() -> &'static [&'static str] {
    &["tag_a", "tag_b"]
}
