pub fn critical_into(dst: &mut [f32]) {
    let tmp = vec![0.0f32; dst.len()];
    dst[0] = tmp[0];
}
