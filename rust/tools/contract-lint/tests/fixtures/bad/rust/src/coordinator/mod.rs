pub fn risky() -> i32 {
    Some(1).unwrap()
}
