#![forbid(unsafe_code)]

pub fn fine() -> i32 {
    Some(1).unwrap_or(0)
}
