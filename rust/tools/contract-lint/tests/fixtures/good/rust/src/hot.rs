pub fn critical_into(dst: &mut [f32]) {
    for v in dst.iter_mut() {
        // SAFETY: fixture demo — reading through a live &mut is sound.
        *v = unsafe { core::ptr::read(v) };
    }
}
