//! Acceptance tests for the contract linter: the bad fixture tree must
//! fail with precise `file:line: [rule]` diagnostics (one per planted
//! violation), the good tree must pass clean, and — the gate itself —
//! the real repository must lint clean.

use std::path::PathBuf;

use contract_lint::{lint_repo, LintConfig};

fn fixture(name: &str) -> LintConfig {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures").join(name);
    LintConfig { manifest: root.join("hotpath.txt"), root }
}

#[test]
fn bad_fixture_fails_with_file_line_diagnostics() {
    let diags = lint_repo(&fixture("bad")).expect("bad fixture lints");
    let rendered: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
    let has = |frag: &str| rendered.iter().any(|d| d.contains(frag));

    // One planted violation per rule, each pinned to its exact line.
    assert!(has("rust/src/bad_unsafe.rs:3: [safety-comment]"), "{rendered:#?}");
    assert!(has("rust/src/spawny.rs:2: [thread-containment]"), "{rendered:#?}");
    assert!(has("rust/src/coordinator/mod.rs:2: [coordinator-unwrap]"), "{rendered:#?}");
    assert!(has("rust/src/coordinator/mod.rs:1: [forbid-unsafe]"), "{rendered:#?}");
    assert!(has("rust/src/hot.rs:2: [hotpath-alloc]"), "{rendered:#?}");
    // A manifest entry whose fn does not exist is itself a violation.
    assert!(has("[hotpath-alloc] manifest fn `missing_fn` not found"), "{rendered:#?}");
    // tag_b is registered but appears in no test.
    assert!(has("rust/src/runtime/native.rs:1: [verify-tags]"), "{rendered:#?}");
    assert!(has("\"tag_b\""), "{rendered:#?}");

    assert_eq!(diags.len(), 7, "exactly the planted violations: {rendered:#?}");
}

#[test]
fn good_fixture_is_clean() {
    let diags = lint_repo(&fixture("good")).expect("good fixture lints");
    assert!(diags.is_empty(), "{:#?}", diags.iter().map(ToString::to_string).collect::<Vec<_>>());
}

/// The gate: the actual repository holds every contract. This runs
/// under `cargo test -p contract-lint`, and the same check runs as the
/// blocking `cargo run -p contract-lint` CI step.
#[test]
fn real_repo_lints_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..").join("..");
    let manifest = root.join("rust").join("tools").join("contract-lint").join("hotpath.txt");
    let diags = lint_repo(&LintConfig { root, manifest }).expect("repo lints");
    assert!(
        diags.is_empty(),
        "contract violations:\n{}",
        diags.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
}
