//! Bench multicore — measured speedup curve of the tile-parallel native
//! kernels over the serial ones, on the BERT-tiny FFN workload
//! (seq 128, d_model 128, d_ff 512, block 16). The execution-side
//! counterpart of the simulator's Fig. 7 multi-core scaling: future PRs
//! track the measured curve against the paper's.
//!
//! Each core count runs on a **persistent worker pool** (built once via
//! `with_cores`, reused across every sample) and a reused workspace
//! lane, with the output landing in a preallocated tensor
//! (`forward_into`) — the serving configuration. The bench installs the
//! counting global allocator and asserts `steady_allocs = 0` across
//! warm forwards at every width (alongside the determinism contract:
//! every parallel forward is bitwise identical to the serial one), so
//! the timed samples measure kernels, not allocator churn.
//!
//! Run: `cargo bench --bench multicore [-- --cores N]`
//! (`--cores N` measures just N workers against the serial baseline;
//! the default sweeps 2/4/8 plus the host's available parallelism.)
//! Greppable summary: lines starting `multicore-speedup`.

use bwma::runtime::{available_cores, NativeModel, Tensor};
use bwma::util::alloc::{heap_allocs_total, CountingAllocator};
use bwma::util::{bench, XorShift64};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn core_counts() -> Vec<usize> {
    let args: Vec<String> = std::env::args().collect();
    if let Some(n) = args
        .iter()
        .position(|a| a == "--cores")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
    {
        return vec![n];
    }
    let mut counts = vec![2usize, 4, 8];
    let host = available_cores();
    if !counts.contains(&host) && host > 1 {
        counts.push(host);
        counts.sort_unstable();
    }
    counts
}

/// Assert zero heap allocations across `iters` warm forwards, returning
/// the observed delta (printed as `steady_allocs`).
fn assert_steady_allocs(m: &NativeModel, x: &Tensor, out: &mut Tensor, iters: usize) -> usize {
    // Warm-up: lane creation, page faults, first-use paths.
    for _ in 0..2 {
        m.forward_into(x, out).unwrap();
    }
    let before = heap_allocs_total();
    for _ in 0..iters {
        m.forward_into(x, out).unwrap();
    }
    let allocs = heap_allocs_total() - before;
    assert_eq!(allocs, 0, "warm forwards must not allocate at {} cores", m.cores());
    allocs
}

fn main() {
    // BERT-tiny FFN block.
    let (seq, d_model, d_ff, block) = (128usize, 128usize, 512usize, 16usize);
    let model = NativeModel::new(seq, d_model, d_ff, block, 0xB117).unwrap();
    let mut rng = XorShift64::new(0xB112);
    let mut data = vec![0.0f32; seq * d_model];
    rng.fill_f32(&mut data);
    let x = Tensor::new(vec![seq, d_model], data);
    let mut out = Tensor::zeros(vec![seq, d_model]);

    println!(
        "# multicore: BERT-tiny FFN (seq {seq}, d_model {d_model}, d_ff {d_ff}, block {block}); \
         host parallelism {}",
        available_cores()
    );

    // The base model's persistent pool is width 1 — the serial baseline.
    let steady = assert_steady_allocs(&model, &x, &mut out, 10);
    let serial = bench::bench("multicore/ffn-forward-1core", 2, 7, || {
        model.forward_into(&x, &mut out).unwrap()
    });
    let baseline = serial.median();
    let expect = model.forward_with_cores(&x, 1).unwrap();

    println!("multicore-speedup cores=1 median={baseline:?} speedup=1.00 steady_allocs={steady}");
    for cores in core_counts() {
        // Persistent pool for this width — built once, reused by every
        // sample below.
        let m = model.clone().with_cores(cores).unwrap();
        let got = m.forward(&x).unwrap();
        let bitwise = expect
            .data
            .iter()
            .zip(&got.data)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(bitwise, "parallel forward at {cores} cores diverged from serial");
        let steady = assert_steady_allocs(&m, &x, &mut out, 10);
        let s = bench::bench(&format!("multicore/ffn-forward-{cores}core"), 2, 7, || {
            m.forward_into(&x, &mut out).unwrap()
        });
        let speedup = baseline.as_secs_f64() / s.median().as_secs_f64();
        println!(
            "multicore-speedup cores={cores} median={:?} speedup={speedup:.2} steady_allocs={steady}",
            s.median()
        );
    }
}
