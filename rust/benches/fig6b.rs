//! Bench fig6b — regenerates paper Fig. 6b (execution time vs core
//! count, SA16x16, both layouts) at paper scale, then times the
//! multi-core engine on the reduced config.
//!
//! Run: `cargo bench --bench fig6b`

use bwma::accel::AccelKind;
use bwma::coordinator::experiment::{fig6b, Scale};
use bwma::layout::Layout;
use bwma::sim::{simulate, SimConfig};
use bwma::util::bench;

fn main() {
    let (out, _) = bench::once("fig6b/paper-series", || fig6b(Scale::Paper));
    out.print();

    for cores in [1usize, 2, 4] {
        bench::bench(&format!("sim/tiny/sa16-bwma-{cores}core"), 1, 5, || {
            simulate(&SimConfig::tiny(AccelKind::Sa { b: 16 }, Layout::Bwma, cores)).total_cycles
        });
    }
}
