//! Bench fig7 — regenerates paper Fig. 7 (per-component execution-time
//! distribution, RWMA vs BWMA pies on SA16x16 single-core).
//!
//! Run: `cargo bench --bench fig7`

use bwma::coordinator::experiment::{fig7, Scale};
use bwma::util::bench;

fn main() {
    let (out, _) = bench::once("fig7/paper-series", || fig7(Scale::Paper));
    out.print();

    bench::bench("fig7/tiny", 1, 3, || fig7(Scale::Tiny).notes.len());
}
