//! Bench serving — throughput and tail latency of the two batcher
//! engines on the same encoder family: the fixed engine (fuse + pad to
//! a compiled variant) on uniform-length load, and the continuous
//! engine (length buckets, per-sequence lane refill) on both uniform
//! and mixed-length load. The mixed-length scenario is the one the
//! continuous engine exists for: the fixed engine would pad every
//! request to the longest variant, the continuous engine runs each at
//! its own length.
//!
//! Every scenario asserts the serving contracts while it measures:
//! nothing shed, nothing failed, nothing rejected, and **zero threads
//! spawned across the measured window** (the flood rides the persistent
//! pool built at warm-up).
//!
//! Run: `cargo bench --bench serving [-- --cores N]`
//! Greppable summary: lines starting `serving-throughput`.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use bwma::coordinator::server::BatchRunner;
use bwma::coordinator::{LatencyStats, Server, ServerConfig};
use bwma::runtime::{available_cores, NativeModel, Tensor, WorkerPool};
use bwma::util::XorShift64;

const D_MODEL: usize = 64;
const HEADS: usize = 2;
const D_FF: usize = 128;
const LAYERS: usize = 1;
const BLOCK: usize = 16;
const SEED: u64 = 0xBE4C;
const REQUESTS: usize = 256;

fn cores_arg() -> usize {
    let args: Vec<String> = std::env::args().collect();
    if let Some(n) = args
        .iter()
        .position(|a| a == "--cores")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
    {
        return n;
    }
    available_cores().clamp(2, 4)
}

fn encoder(seq: usize) -> NativeModel {
    NativeModel::new_encoder(seq, D_MODEL, HEADS, D_FF, LAYERS, BLOCK, SEED).unwrap()
}

/// Fixed engine: one 64-length model behind padded variants {1,2,4,8}.
fn start_fixed(cores: usize) -> Server {
    let model = Arc::new(encoder(64).with_cores(cores).unwrap());
    let in_shape = model.in_shape();
    let out_shape = model.out_shape();
    let cfg = ServerConfig {
        max_batch: 8,
        batch_timeout: Duration::from_millis(1),
        ..Default::default()
    };
    Server::start(cfg, move || {
        let mut variants: BTreeMap<usize, Box<dyn BatchRunner>> = BTreeMap::new();
        for bsz in [1usize, 2, 4, 8] {
            variants.insert(bsz, Box::new(model.clone()));
        }
        Ok((variants, in_shape, out_shape))
    })
    .unwrap()
}

/// Continuous engine: one model per bucket, all on one shared pool.
fn start_continuous(buckets: &[usize], cores: usize) -> Server {
    let buckets = buckets.to_vec();
    Server::start_continuous(ServerConfig::default(), move || {
        let mut models: Vec<NativeModel> = Vec::new();
        for &seq in &buckets {
            let m = match models.first() {
                None => encoder(seq).with_cores(cores)?,
                Some(first) => encoder(seq).with_pool(Arc::clone(first.pool())),
            };
            models.push(m);
        }
        Ok(models)
    })
    .unwrap()
}

fn rand_input(rng: &mut XorShift64, seq: usize) -> Tensor {
    let mut data = vec![0.0f32; seq * D_MODEL];
    rng.fill_f32(&mut data);
    Tensor::new(vec![seq, D_MODEL], data)
}

fn inputs(rng: &mut XorShift64, n: usize, buckets: &[usize]) -> Vec<Tensor> {
    (0..n).map(|i| rand_input(rng, buckets[i % buckets.len()])).collect()
}

/// Submit the whole flood, await every response; returns requests/s and
/// the server-side (queue + exec) latency distribution.
fn flood(server: &Server, load: &[Tensor]) -> (f64, LatencyStats) {
    let start = Instant::now();
    let rxs: Vec<_> = load.iter().map(|x| server.submit(x.clone())).collect();
    let mut lat = Vec::with_capacity(load.len());
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        lat.push(resp.queue_time + resp.exec_time);
    }
    let elapsed = start.elapsed().as_secs_f64();
    (load.len() as f64 / elapsed, LatencyStats::from_samples(lat))
}

fn run_scenario(engine: &str, load_name: &str, server: Server, load: &[Tensor]) {
    // Warm-up: build pools and workspace lanes outside the window.
    flood(&server, &load[..load.len().min(16)]);
    let spawned = WorkerPool::threads_spawned_total();
    let (rps, lat) = flood(&server, load);
    let steady_spawns = WorkerPool::threads_spawned_total() - spawned;
    let metrics = server.shutdown().unwrap();
    assert_eq!(steady_spawns, 0, "{engine}/{load_name}: measured window spawned threads");
    assert_eq!(metrics.failed, 0, "{engine}/{load_name}: requests failed under the bench flood");
    assert_eq!(metrics.shed, 0, "{engine}/{load_name}: the default queue depth must absorb this");
    assert_eq!(metrics.rejected, 0, "{engine}/{load_name}: every bench request is well-formed");
    let batching = if metrics.batches > 0 {
        format!(" mean_batch={:.2}", metrics.mean_batch_size())
    } else {
        String::new()
    };
    println!(
        "serving-throughput engine={engine} load={load_name} req_s={rps:.0} p50={:?} p99={:?} \
         steady_spawns={steady_spawns}{batching}",
        lat.p50(),
        lat.p99(),
    );
}

fn main() {
    let cores = cores_arg();
    let mut rng = XorShift64::new(0xBE4D);
    println!(
        "# serving: encoder (d_model {D_MODEL}, heads {HEADS}, d_ff {D_FF}, layers {LAYERS}, \
         block {BLOCK}); {REQUESTS} requests/scenario, {cores} cores"
    );
    let uniform = inputs(&mut rng, REQUESTS, &[64]);
    run_scenario("fixed", "uniform-64", start_fixed(cores), &uniform);
    run_scenario("continuous", "uniform-64", start_continuous(&[64], cores), &uniform);
    let mixed = inputs(&mut rng, REQUESTS, &[32, 64, 96]);
    run_scenario("continuous", "mixed-32/64/96", start_continuous(&[32, 64, 96], cores), &mixed);
}
