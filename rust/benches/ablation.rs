//! Ablation bench — design-choice studies beyond the paper's own figures
//! (see rust/README.md for the experiment index; reduced geometry keeps
//! the sweep fast — shapes, not absolute cycles, are the subject):
//!
//! * kernel-size sweep 4..32 (where does the BWMA advantage peak?)
//! * hardware stream prefetcher on/off (does BWMA's win survive one?)
//! * L2 capacity sweep (is the effect an L2-size artifact?)
//! * element width 1/2/4 bytes (int8 vs fp16 vs fp32 tensors)
//! * L1 set-index hashing on/off (power-of-two stride aliasing)
//! * replacement policy LRU vs tree-PLRU
//!
//! Run: `cargo bench --bench ablation`

use bwma::accel::AccelKind;
use bwma::layout::Layout;
use bwma::mem::replacement::Policy;
use bwma::sim::{simulate, SimConfig};
use bwma::util::table;

fn speedup(mut mk: impl FnMut(Layout) -> SimConfig) -> (f64, u64, u64) {
    let r = simulate(&mk(Layout::Rwma));
    let b = simulate(&mk(Layout::Bwma));
    (r.total_cycles as f64 / b.total_cycles as f64, r.total_cycles, b.total_cycles)
}

fn main() {
    // --- kernel-size sweep ---
    let mut rows = Vec::new();
    for b in [4usize, 8, 16, 32] {
        let (s, r, w) = speedup(|l| SimConfig::tiny(AccelKind::Sa { b }, l, 1));
        rows.push(vec![format!("SA{b}x{b}"), table::cycles(r), table::cycles(w), format!("{s:.2}x")]);
    }
    println!("== ablation: kernel size (tiny geometry)");
    print!("{}", table::render(&["accel", "RWMA", "BWMA", "speedup"], &rows));

    // --- prefetcher on/off ---
    let mut rows = Vec::new();
    for pf in [false, true] {
        let (s, r, w) = speedup(|l| {
            let mut c = SimConfig::tiny(AccelKind::Sa { b: 16 }, l, 1);
            c.mem.prefetch.enabled = pf;
            c
        });
        rows.push(vec![
            if pf { "stream prefetcher" } else { "no prefetcher (paper)" }.into(),
            table::cycles(r),
            table::cycles(w),
            format!("{s:.2}x"),
        ]);
    }
    println!("\n== ablation: hardware prefetcher");
    print!("{}", table::render(&["config", "RWMA", "BWMA", "speedup"], &rows));

    // --- L2 capacity ---
    let mut rows = Vec::new();
    for l2_kb in [256usize, 512, 1024, 4096] {
        let (s, r, w) = speedup(|l| {
            let mut c = SimConfig::tiny(AccelKind::Sa { b: 16 }, l, 1);
            c.mem.l2.size = l2_kb * 1024;
            c
        });
        rows.push(vec![format!("{l2_kb} KiB"), table::cycles(r), table::cycles(w), format!("{s:.2}x")]);
    }
    println!("\n== ablation: shared L2 capacity");
    print!("{}", table::render(&["L2", "RWMA", "BWMA", "speedup"], &rows));

    // --- element width ---
    let mut rows = Vec::new();
    for elem in [1usize, 2, 4] {
        let (s, r, w) = speedup(|l| {
            let mut c = SimConfig::tiny(AccelKind::Sa { b: 16 }, l, 1);
            c.bert.elem = elem;
            c
        });
        rows.push(vec![
            format!("{} ({} B)", ["int8", "fp16", "fp32"][elem.trailing_zeros() as usize], elem),
            table::cycles(r),
            table::cycles(w),
            format!("{s:.2}x"),
        ]);
    }
    println!("\n== ablation: element width (an RWMA tile row = b·elem bytes of a 64 B line)");
    print!("{}", table::render(&["dtype", "RWMA", "BWMA", "speedup"], &rows));

    // --- L1 index hashing ---
    let mut rows = Vec::new();
    for hash in [true, false] {
        let (s, r, w) = speedup(|l| {
            let mut c = SimConfig::tiny(AccelKind::Sa { b: 16 }, l, 1);
            c.mem.l1d.index_hash = hash;
            c.mem.l2.index_hash = hash;
            c
        });
        rows.push(vec![
            if hash { "XOR-hashed sets" } else { "direct-indexed sets" }.into(),
            table::cycles(r),
            table::cycles(w),
            format!("{s:.2}x"),
        ]);
    }
    println!("\n== ablation: L1/L2 set-index hashing");
    print!("{}", table::render(&["index", "RWMA", "BWMA", "speedup"], &rows));

    // --- replacement policy ---
    let mut rows = Vec::new();
    for pol in [Policy::Lru, Policy::TreePlru] {
        let (s, r, w) = speedup(|l| {
            let mut c = SimConfig::tiny(AccelKind::Sa { b: 16 }, l, 1);
            c.mem.l1d.policy = pol;
            c.mem.l2.policy = pol;
            c
        });
        rows.push(vec![format!("{pol:?}"), table::cycles(r), table::cycles(w), format!("{s:.2}x")]);
    }
    println!("\n== ablation: replacement policy");
    print!("{}", table::render(&["policy", "RWMA", "BWMA", "speedup"], &rows));
}
