//! Bench decode — incremental generative decoding on the native stack:
//! tokens/sec against KV-cache depth (the per-token cost grows with the
//! attended context), and the batch 1..8 latency-bound regime (each
//! sequence decodes one token per round on its own session/lane — the
//! shape the continuous batcher's lane refills produce). The bench
//! installs the counting global allocator and asserts every measured
//! window spawns **zero threads and performs zero heap allocations**
//! (the `steady_allocs=0 / steady_spawns=0` serving contract), plus the
//! determinism contract: pooled decode steps are bitwise identical to
//! serial ones.
//!
//! Run: `cargo bench --bench decode`
//! Greppable summary: lines starting `decode-context` / `decode-batch`.

use std::time::Instant;

use bwma::runtime::{available_cores, NativeModel, WorkerPool};
use bwma::util::alloc::{heap_allocs_total, CountingAllocator};
use bwma::util::XorShift64;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Decode steps per measured window.
const STEPS: usize = 29;

/// Prefill `depth` tokens, warm three steps, then measure `STEPS` decode
/// steps under the zero-allocation / zero-spawn contract. Returns the
/// window's wall time and the final step's output row (the bitwise
/// cross-check between pool widths).
fn run_window(
    model: &NativeModel,
    prompt: &[f32],
    depth: usize,
    token: &[f32],
    d: usize,
) -> (f64, Vec<f32>) {
    let mut sess = model.begin_decode().unwrap();
    let mut pre = vec![0.0f32; depth * d];
    model.prefill_into(&mut sess, &prompt[..depth * d], depth, &mut pre).unwrap();
    let mut out = vec![0.0f32; d];
    for _ in 0..3 {
        model.decode_step_into(&mut sess, token, &mut out).unwrap();
    }
    let spawned_before = WorkerPool::threads_spawned_total();
    let allocs_before = heap_allocs_total();
    let t0 = Instant::now();
    for _ in 0..STEPS {
        model.decode_step_into(&mut sess, token, &mut out).unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let spawned = WorkerPool::threads_spawned_total() - spawned_before;
    let allocs = heap_allocs_total() - allocs_before;
    assert_eq!(spawned, 0, "steady decode steps must not spawn threads");
    assert_eq!(allocs, 0, "warm decode steps must not allocate");
    model.end_decode(sess);
    (dt, out)
}

fn main() {
    let (d_model, heads, d_ff, block, layers, ctx) =
        (128usize, 2usize, 512usize, 16usize, 2usize, 256usize);
    let model =
        NativeModel::new_decoder(32, d_model, heads, d_ff, layers, block, ctx, 0xDECD).unwrap();
    let mut rng = XorShift64::new(0xDECE);
    let mut prompt = vec![0.0f32; 224 * d_model];
    rng.fill_f32(&mut prompt);
    let mut token = vec![0.0f32; d_model];
    rng.fill_f32(&mut token);

    println!(
        "# decode: d_model {d_model}, {heads} heads, d_ff {d_ff}, block {block}, \
         {layers} layer(s), max-context {ctx}; host parallelism {}",
        available_cores()
    );

    // Tokens/sec vs KV-cache depth, batch 1: serial first (the golden
    // bits), then pooled widths — every pooled window must land on the
    // serial bits exactly. Depth 224 ends the window at position 255,
    // one short of --max-context.
    let depths = [16usize, 64, 128, 224];
    let serial = model.clone().with_cores(1).unwrap();
    let mut golden: Vec<Vec<f32>> = Vec::new();
    for &p in &depths {
        let (dt, out) = run_window(&serial, &prompt, p, &token, d_model);
        println!(
            "decode-context cores=1 context={p} tokens_per_sec={:.0} \
             steady_spawns=0 steady_allocs=0",
            STEPS as f64 / dt
        );
        golden.push(out);
    }
    for cores in [2usize, 4, 8] {
        let m = model.clone().with_cores(cores).unwrap();
        for (gi, &p) in depths.iter().enumerate() {
            let (dt, out) = run_window(&m, &prompt, p, &token, d_model);
            let bitwise = golden[gi].iter().zip(&out).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(bitwise, "pooled decode at {cores} cores diverged from serial at depth {p}");
            println!(
                "decode-context cores={cores} context={p} tokens_per_sec={:.0} \
                 steady_spawns=0 steady_allocs=0",
                STEPS as f64 / dt
            );
        }
    }

    // The latency-bound batch regime: B sessions, one lane each, decode
    // one token per round, round-robin across sequences.
    let cores = available_cores().min(4);
    let m = model.clone().with_cores(cores).unwrap();
    for batch in 1usize..=8 {
        m.reserve_workspace_lanes(batch);
        let mut sessions = Vec::new();
        let mut pre = vec![0.0f32; 32 * d_model];
        for s in 0..batch {
            let mut sess = m.begin_decode().unwrap();
            // Staggered prompt slices so every sequence carries its own
            // history.
            let lo = s * 16 * d_model;
            m.prefill_into(&mut sess, &prompt[lo..lo + 32 * d_model], 32, &mut pre).unwrap();
            sessions.push(sess);
        }
        let mut out = vec![0.0f32; d_model];
        for _ in 0..3 {
            for sess in &mut sessions {
                m.decode_step_into(sess, &token, &mut out).unwrap();
            }
        }
        let spawned_before = WorkerPool::threads_spawned_total();
        let allocs_before = heap_allocs_total();
        let t0 = Instant::now();
        for _ in 0..STEPS {
            for sess in &mut sessions {
                m.decode_step_into(sess, &token, &mut out).unwrap();
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let spawned = WorkerPool::threads_spawned_total() - spawned_before;
        let allocs = heap_allocs_total() - allocs_before;
        assert_eq!(spawned, 0, "steady batch decode must not spawn threads");
        assert_eq!(allocs, 0, "warm batch decode must not allocate");
        for sess in sessions {
            m.end_decode(sess);
        }
        println!(
            "decode-batch cores={cores} batch={batch} tokens_per_sec={:.0} \
             per_token_latency_us={:.1} steady_spawns={spawned} steady_allocs={allocs}",
            (batch * STEPS) as f64 / dt,
            dt * 1e6 / STEPS as f64
        );
    }
}
