//! Bench fig6a — regenerates paper Fig. 6a (execution time per
//! accelerator, RWMA vs BWMA, single core) at paper scale, then times
//! the simulator harness itself on the reduced config.
//!
//! Run: `cargo bench --bench fig6a`

use bwma::accel::AccelKind;
use bwma::coordinator::experiment::{fig6a, Scale};
use bwma::layout::Layout;
use bwma::sim::{simulate, SimConfig};
use bwma::util::bench;

fn main() {
    // The paper series (full BERT-base geometry).
    let (out, _) = bench::once("fig6a/paper-series", || fig6a(Scale::Paper));
    out.print();

    // Harness timing: simulator throughput on the reduced config.
    for (label, accel, layout) in [
        ("sim/tiny/sa16-rwma", AccelKind::Sa { b: 16 }, Layout::Rwma),
        ("sim/tiny/sa16-bwma", AccelKind::Sa { b: 16 }, Layout::Bwma),
        ("sim/tiny/sa8-bwma", AccelKind::Sa { b: 8 }, Layout::Bwma),
    ] {
        bench::bench(label, 1, 5, || simulate(&SimConfig::tiny(accel, layout, 1)).total_cycles);
    }
}
