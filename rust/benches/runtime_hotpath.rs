//! Runtime hot-path bench: the PJRT execution path the serving layer
//! lives on — artifact compile time, per-inference latency of the
//! blocked-GEMM kernel and of the batch-variant encoders, and the
//! host-side layout pack/unpack throughput.
//!
//! Run: `cargo bench --bench runtime_hotpath` (needs `make artifacts`).

use bwma::runtime::{artifacts_dir, GoldenSet, Runtime, Tensor};
use bwma::util::{bench, XorShift64};

fn main() {
    let dir = artifacts_dir().expect("run `make artifacts` first");
    let rt = Runtime::cpu().expect("PJRT CPU client");

    // Artifact compile cost (one-time, off the request path).
    let (gemm, _) = bench::once("compile/bwma_gemm_b16", || {
        rt.load_hlo(&dir.join("bwma_gemm_b16.hlo.txt")).unwrap()
    });
    let (enc1, _) = bench::once("compile/encoder_b16_batch1", || {
        rt.load_hlo(&dir.join("encoder_jnp_b16_batch1.hlo.txt")).unwrap()
    });
    let (enc8, _) = bench::once("compile/encoder_b16_batch8", || {
        rt.load_hlo(&dir.join("encoder_jnp_b16_batch8.hlo.txt")).unwrap()
    });

    // Kernel execution latency.
    let g = GoldenSet::load(&dir, "bwma_gemm_b16").unwrap();
    let inputs = g.inputs();
    let out_shape = g.expected().shape.clone();
    bench::bench("exec/bwma_gemm_b16 (64x64x64)", 2, 10, || {
        gemm.run1(&inputs, out_shape.clone()).unwrap().data[0]
    });

    // Encoder execution latency per batch variant.
    for (label, exe, tag) in [
        ("exec/encoder_b16 batch1", &enc1, "encoder_jnp_b16_batch1"),
        ("exec/encoder_b16 batch8", &enc8, "encoder_jnp_b16_batch8"),
    ] {
        let g = GoldenSet::load(&dir, tag).unwrap();
        let inputs = g.inputs();
        let out_shape = g.expected().shape.clone();
        let s = bench::bench(label, 1, 5, || exe.run1(&inputs, out_shape.clone()).unwrap().data[0]);
        let batch: usize = g.tensors["in_x"].shape[0];
        println!(
            "  → {:.1} seq/s at batch {batch}",
            batch as f64 / s.median().as_secs_f64()
        );
    }

    // Host-side layout pack/unpack (the only per-request host transform).
    let mut rng = XorShift64::new(1);
    let mut data = vec![0.0f32; 512 * 768];
    rng.fill_f32(&mut data);
    let t = Tensor::new(vec![512, 768], data);
    let s = bench::bench("host/pack_blocked 512x768 f32", 3, 20, || t.pack_blocked(16).unwrap().data[0]);
    let mb = (512.0 * 768.0 * 4.0) / 1e6;
    println!("  → {:.0} MB/s pack throughput", mb / s.median().as_secs_f64());
    let p = t.pack_blocked(16).unwrap();
    bench::bench("host/unpack_blocked 512x768 f32", 3, 20, || p.unpack_blocked().unwrap().data[0]);
}
