//! Bench fig8 — regenerates paper Fig. 8 (memory accesses and misses per
//! hierarchy level, log-scale bars) plus the conversion-overhead check
//! (§3.2) that shares its configuration.
//!
//! Run: `cargo bench --bench fig8`

use bwma::coordinator::experiment::{convert_overhead, fig8, headline, Scale};
use bwma::util::bench;

fn main() {
    let (out, _) = bench::once("fig8/paper-series", || fig8(Scale::Paper));
    out.print();

    let (out, _) = bench::once("convert-overhead/paper", || convert_overhead(Scale::Paper));
    out.print();

    let (out, _) = bench::once("headline/paper", || headline(Scale::Paper));
    out.print();
}
