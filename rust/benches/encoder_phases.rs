//! Bench encoder_phases — per-phase wall time of the **native** encoder
//! layer (QKV, Kᵀ, QKᵀ, softmax, AV, projection, Add/Norm, FFN) at
//! 1/2/4/8 cores, printed next to the **simulator's** phase breakdown
//! for the same dimensions — the execution-side counterpart of the
//! paper's Fig. 7 per-component split, now measurable phase-by-phase
//! because `NativeModel::new_encoder` runs the same ten phases the
//! simulator's `LayerPhases` models.
//!
//! Each core count runs on a **persistent worker pool** (the serving
//! configuration): phases wake long-lived workers — ten wake-ups per
//! layer — instead of spawning one `thread::scope` per head-kernel as
//! the pre-pool code did (ISSUE 4). The bench asserts the steady state
//! spawns no threads, and the determinism contract while it measures:
//! every parallel forward is bitwise identical to the serial one.
//!
//! Run: `cargo bench --bench encoder_phases`
//! Greppable summary: lines starting `encoder-phase` / `encoder-speedup`.

use bwma::accel::AccelKind;
use bwma::layout::Layout;
use bwma::runtime::{available_cores, NativeModel, Tensor, WorkerPool};
use bwma::sim::{simulate, SimConfig};
use bwma::util::XorShift64;
use bwma::workload::BertConfig;

fn main() {
    // A scaled-down encoder layer (same structure as BERT-base): the
    // native model and the simulator run identical dimensions.
    let (seq, d_model, heads, d_ff, block, layers) = (128usize, 128usize, 2usize, 512usize, 16usize, 1usize);
    let d_head = d_model / heads;
    let model = NativeModel::new_encoder(seq, d_model, heads, d_ff, layers, block, 0xE4C).unwrap();
    let mut rng = XorShift64::new(0xE4D);
    let mut data = vec![0.0f32; seq * d_model];
    rng.fill_f32(&mut data);
    let x = Tensor::new(vec![seq, d_model], data);

    println!(
        "# encoder_phases: seq {seq}, d_model {d_model}, {heads} heads (d_head {d_head}), \
         d_ff {d_ff}, block {block}, {layers} layer(s); host parallelism {}",
        available_cores()
    );

    // Simulator breakdown for the same dimensions (1 core, BWMA, SA16).
    let mut cfg = SimConfig::tiny(AccelKind::Sa { b: block }, Layout::Bwma, 1);
    cfg.bert = BertConfig { seq, d_model, heads, d_head, d_ff, layers, elem: 1 };
    cfg.sim_layers = layers;
    let sim = simulate(&cfg);
    let sim_share = |name: &str| -> f64 {
        sim.phases
            .iter()
            .find(|p| p.name == name)
            .map(|p| p.cycles as f64 / sim.total_cycles as f64)
            .unwrap_or(0.0)
    };

    let (expect, _) = model.forward_timed(&x, 1).unwrap();
    let mut baseline = f64::NAN;
    for cores in [1usize, 2, 4, 8] {
        // A persistent pool per core count (the serving configuration);
        // after warm-up, the measured runs must spawn zero threads.
        let m = model.clone().with_cores(cores).unwrap();
        let _ = m.forward_timed(&x, cores).unwrap();
        let spawned_before = WorkerPool::threads_spawned_total();
        const RUNS: usize = 5;
        let mut acc: Option<bwma::runtime::PhaseTimings> = None;
        for _ in 0..RUNS {
            let (out, timings) = m.forward_timed(&x, cores).unwrap();
            let bitwise =
                expect.data.iter().zip(&out.data).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(bitwise, "parallel encoder at {cores} cores diverged from serial");
            acc = Some(match acc {
                None => timings,
                Some(prev) => {
                    // Keep the run with the smaller total (min-of-N, the
                    // usual bench noise reduction).
                    if timings.total() < prev.total() {
                        timings
                    } else {
                        prev
                    }
                }
            });
        }
        let spawned = WorkerPool::threads_spawned_total() - spawned_before;
        assert_eq!(spawned, 0, "steady-state pooled forwards must not spawn threads");
        let timings = acc.unwrap();
        let total = timings.total();
        if cores == 1 {
            baseline = total.as_secs_f64();
        }
        println!(
            "encoder-speedup cores={cores} total={total:?} speedup={:.2} steady_spawns={spawned}",
            baseline / total.as_secs_f64()
        );
        for (name, dt) in timings.entries() {
            let native_share = dt.as_secs_f64() / total.as_secs_f64();
            println!(
                "encoder-phase cores={cores} phase={name:?} native={dt:?} \
                 native_share={native_share:.3} sim_share={:.3}",
                sim_share(name)
            );
        }
    }
    println!(
        "# sim total: {} cycles, non-GEMM share {:.1}% (native shares above are wall-clock)",
        sim.total_cycles,
        100.0 * sim.non_gemm_share()
    );
}
