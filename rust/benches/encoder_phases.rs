//! Bench encoder_phases — per-phase wall time of the **native** encoder
//! layer (QKV, Kᵀ, QKᵀ, softmax, AV, projection, Add/Norm, FFN) at
//! 1/2/4/8 cores, printed next to the **simulator's** phase breakdown
//! for the same dimensions — the execution-side counterpart of the
//! paper's Fig. 7 per-component split, now measurable phase-by-phase
//! because `NativeModel::new_encoder` runs the same ten phases the
//! simulator's `LayerPhases` models.
//!
//! Each core count runs on a **persistent worker pool** and a reused
//! **workspace lane** (the serving configuration): phases wake
//! long-lived workers — ten wake-ups per layer — and every intermediate
//! lives in preplanned arenas. The bench installs the counting global
//! allocator and asserts the steady state spawns **zero threads and
//! performs zero heap allocations** while it measures
//! (`forward_timed_into` + `PhaseTimings::reset` keep even the timing
//! accumulation off the heap), plus the determinism contract: every
//! parallel forward is bitwise identical to the serial one.
//!
//! Run: `cargo bench --bench encoder_phases`
//! Greppable summary: lines starting `encoder-phase` / `encoder-speedup`.

use std::time::Duration;

use bwma::accel::AccelKind;
use bwma::layout::Layout;
use bwma::runtime::{available_cores, NativeModel, PhaseTimings, Tensor, WorkerPool};
use bwma::sim::{simulate, SimConfig};
use bwma::util::alloc::{heap_allocs_total, CountingAllocator};
use bwma::util::XorShift64;
use bwma::workload::BertConfig;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn main() {
    // A scaled-down encoder layer (same structure as BERT-base): the
    // native model and the simulator run identical dimensions.
    let (seq, d_model, heads, d_ff, block, layers) = (128usize, 128usize, 2usize, 512usize, 16usize, 1usize);
    let d_head = d_model / heads;
    let model = NativeModel::new_encoder(seq, d_model, heads, d_ff, layers, block, 0xE4C).unwrap();
    let mut rng = XorShift64::new(0xE4D);
    let mut data = vec![0.0f32; seq * d_model];
    rng.fill_f32(&mut data);
    let x = Tensor::new(vec![seq, d_model], data);

    println!(
        "# encoder_phases: seq {seq}, d_model {d_model}, {heads} heads (d_head {d_head}), \
         d_ff {d_ff}, block {block}, {layers} layer(s); host parallelism {}",
        available_cores()
    );

    // Simulator breakdown for the same dimensions (1 core, BWMA, SA16).
    let mut cfg = SimConfig::tiny(AccelKind::Sa { b: block }, Layout::Bwma, 1);
    cfg.bert = BertConfig { seq, d_model, heads, d_head, d_ff, layers, elem: 1 };
    cfg.sim_layers = layers;
    let sim = simulate(&cfg);
    let sim_share = |name: &str| -> f64 {
        sim.phases
            .iter()
            .find(|p| p.name == name)
            .map(|p| p.cycles as f64 / sim.total_cycles as f64)
            .unwrap_or(0.0)
    };

    let (expect, _) = model.forward_timed(&x, 1).unwrap();
    let mut out = Tensor::zeros(vec![seq, d_model]);
    let mut baseline = f64::NAN;
    for cores in [1usize, 2, 4, 8] {
        // A persistent pool + reused lane per core count (the serving
        // configuration); after warm-up, the measured runs must spawn
        // zero threads and allocate nothing.
        let m = model.clone().with_cores(cores).unwrap();
        let mut cur = PhaseTimings::default();
        let mut best = PhaseTimings::default();
        // Two warm-up runs populate both timing buffers (their one-time
        // entry allocations), the workspace lane, and first-use paths.
        // `best` is then zeroed so the unmeasured warm-up numbers cannot
        // win the min-of-N below (the ZERO guard admits the first
        // measured run).
        m.forward_timed_into(&x, cores, &mut out, &mut cur).unwrap();
        std::mem::swap(&mut best, &mut cur);
        cur.reset();
        m.forward_timed_into(&x, cores, &mut out, &mut cur).unwrap();
        best.reset();
        let spawned_before = WorkerPool::threads_spawned_total();
        let allocs_before = heap_allocs_total();
        const RUNS: usize = 5;
        for _ in 0..RUNS {
            cur.reset();
            m.forward_timed_into(&x, cores, &mut out, &mut cur).unwrap();
            let bitwise =
                expect.data.iter().zip(&out.data).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(bitwise, "parallel encoder at {cores} cores diverged from serial");
            // Keep the run with the smaller total (min-of-N, the usual
            // bench noise reduction) — a pointer swap, not a copy.
            if best.total() == Duration::ZERO || cur.total() < best.total() {
                std::mem::swap(&mut best, &mut cur);
            }
        }
        let spawned = WorkerPool::threads_spawned_total() - spawned_before;
        let allocs = heap_allocs_total() - allocs_before;
        assert_eq!(spawned, 0, "steady-state pooled forwards must not spawn threads");
        assert_eq!(allocs, 0, "steady-state warm forwards must not allocate");
        let total = best.total();
        if cores == 1 {
            baseline = total.as_secs_f64();
        }
        println!(
            "encoder-speedup cores={cores} total={total:?} speedup={:.2} steady_spawns={spawned} \
             steady_allocs={allocs}",
            baseline / total.as_secs_f64()
        );
        for (name, dt) in best.entries() {
            let native_share = dt.as_secs_f64() / total.as_secs_f64();
            println!(
                "encoder-phase cores={cores} phase={name:?} native={dt:?} \
                 native_share={native_share:.3} sim_share={:.3}",
                sim_share(name)
            );
        }
    }
    println!(
        "# sim total: {} cycles, non-GEMM share {:.1}% (native shares above are wall-clock)",
        sim.total_cycles,
        100.0 * sim.non_gemm_share()
    );
}
