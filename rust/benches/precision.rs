//! Bench precision — f32 vs int8 encoder forward at 1/2/4/8 cores
//! (ISSUE 6). Both precisions run the identical ten-phase pipeline on
//! the identical seed-derived weights; the int8 model packs weights at
//! 1 byte/element (`packed_param_bytes`, printed as `bytes_packed`) and
//! runs i8×i8→i32 GEMMs with fused dequant epilogues over the f32
//! residual/norm/softmax spine.
//!
//! Every measured configuration runs on a persistent worker pool and a
//! reused workspace lane (`forward_into`), with the counting global
//! allocator asserting `steady_allocs = 0` and the pool's spawn counter
//! asserting `steady_spawns = 0` across the warm forwards — for BOTH
//! precisions: the quantized path must not buy its byte savings with
//! allocator or thread churn.
//!
//! Run: `cargo bench --bench precision [-- --cores N]`
//! Greppable summary: lines starting `precision-forward`.

use bwma::runtime::{available_cores, NativeModel, Precision, Tensor, WorkerPool};
use bwma::util::alloc::{heap_allocs_total, CountingAllocator};
use bwma::util::{bench, XorShift64};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn core_counts() -> Vec<usize> {
    let args: Vec<String> = std::env::args().collect();
    if let Some(n) = args
        .iter()
        .position(|a| a == "--cores")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
    {
        return vec![n];
    }
    vec![1usize, 2, 4, 8]
}

/// Zero allocations AND zero thread spawns across `iters` warm forwards.
fn assert_steady(m: &NativeModel, x: &Tensor, out: &mut Tensor, iters: usize) -> (usize, usize) {
    for _ in 0..2 {
        m.forward_into(x, out).unwrap();
    }
    let allocs_before = heap_allocs_total();
    let spawns_before = WorkerPool::threads_spawned_total();
    for _ in 0..iters {
        m.forward_into(x, out).unwrap();
    }
    let allocs = heap_allocs_total() - allocs_before;
    let spawns = WorkerPool::threads_spawned_total() - spawns_before;
    assert_eq!(
        allocs,
        0,
        "warm {} forwards must not allocate at {} cores",
        m.precision(),
        m.cores()
    );
    assert_eq!(
        spawns,
        0,
        "warm {} forwards must not spawn threads at {} cores",
        m.precision(),
        m.cores()
    );
    (allocs, spawns)
}

fn main() {
    // The serving encoder shape (`bwma serve --model encoder`).
    let (seq, d_model, heads, d_ff, layers, block) =
        (64usize, 96usize, 3usize, 192usize, 2usize, 16usize);
    let seed = 0xB118u64;
    let f32_model =
        NativeModel::new_encoder(seq, d_model, heads, d_ff, layers, block, seed).unwrap();
    let int8_model =
        NativeModel::new_encoder_int8(seq, d_model, heads, d_ff, layers, block, seed).unwrap();
    let mut rng = XorShift64::new(0xB119);
    let mut data = vec![0.0f32; seq * d_model];
    rng.fill_f32(&mut data);
    let x = Tensor::new(vec![seq, d_model], data);
    let mut out = Tensor::zeros(vec![seq, d_model]);

    println!(
        "# precision: encoder {layers}x[{seq}x{d_model}, {heads} heads, ff {d_ff}], block {block}; \
         host parallelism {}",
        available_cores()
    );
    println!(
        "# bytes_packed: f32 {} vs int8 {} ({}x reduction in packed weight payload)",
        f32_model.packed_param_bytes(),
        int8_model.packed_param_bytes(),
        f32_model.packed_param_bytes() / int8_model.packed_param_bytes().max(1)
    );

    for cores in core_counts() {
        let mut f32_median = None;
        for (base, precision) in [(&f32_model, Precision::F32), (&int8_model, Precision::Int8)] {
            // Persistent pool for this width — built once, reused by
            // every sample below.
            let m = base.clone().with_cores(cores).unwrap();
            // Determinism contract while measuring: pooled == serial.
            let serial = base.forward_with_cores(&x, 1).unwrap();
            let got = m.forward(&x).unwrap();
            assert!(
                serial.data.iter().zip(&got.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{precision} forward at {cores} cores diverged from serial"
            );
            let (steady_allocs, steady_spawns) = assert_steady(&m, &x, &mut out, 10);
            let s = bench::bench(&format!("precision/{precision}-forward-{cores}core"), 2, 7, || {
                m.forward_into(&x, &mut out).unwrap()
            });
            let median = s.median();
            let vs_f32 = match (precision, f32_median) {
                (Precision::F32, _) => {
                    f32_median = Some(median);
                    1.0
                }
                (Precision::Int8, Some(f)) => f.as_secs_f64() / median.as_secs_f64(),
                (Precision::Int8, None) => 1.0,
            };
            println!(
                "precision-forward precision={precision} cores={cores} median={median:?} \
                 vs_f32={vs_f32:.2} bytes_packed={} steady_allocs={steady_allocs} \
                 steady_spawns={steady_spawns}",
                m.packed_param_bytes()
            );
        }
    }
}
